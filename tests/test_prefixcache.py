"""Prefix cache subsystem: refcounted COW blocks + the radix index.

The acceptance bars:

* ``BlockLedger.free``/``release`` return blocks *actually* released —
  evicting a shared-prefix request reclaims only its unique suffix,
* random interleavings of alloc/share/append/COW/free/insert/evict never
  leak or double-free a block (property: per-block refcounts always
  equal table references + cache references),
* golden lockstep trace: the shared AcceLLM kernel makes identical
  decisions AND the per-instance prefix caches record identical
  hit accounting on the live executor and the simulator adapter,
* under prefix-heavy traffic the live cluster's generated tokens are
  bit-identical with the cache on and off.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.kvstore import BlockLedger, KVStoreError, LineCosts
from repro.models import init_params
from repro.prefixcache import (PrefixCache, PrefixIndex, aligned_hit_lines,
                               chunk_key)
from repro.scheduling import AcceLLMScheduler, LiveCluster
from repro.serving import Request
from repro.sim import H100, InstanceSpec, PerfModel, Simulator
from repro.sim.policies import AcceLLMPolicy
from repro.sim.workload import SimRequest
from repro.workloads import (Batch, Poisson, PrefixReuse, UniformLengths,
                             WorkloadSpec)
from tests._propcheck import given, settings, st

BL = 4  # block_lines for the pure-ledger tests


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _ledger(num_blocks=32, fixed=0):
    return BlockLedger(LineCosts(8.0, fixed, 0), num_blocks, BL)


# ---------------------------------------------------------------------------
# alignment rule
# ---------------------------------------------------------------------------


def test_aligned_hit_lines():
    # block-aligned and strictly inside the prompt
    assert aligned_hit_lines(8, 20, BL) == 8
    assert aligned_hit_lines(8, 8, BL) == 4     # full-prompt hit forbidden
    assert aligned_hit_lines(7, 20, BL) == 4    # rounds down to blocks
    assert aligned_hit_lines(3, 20, BL) == 0
    assert aligned_hit_lines(0, 20, BL) == 0
    assert aligned_hit_lines(100, 1, BL) == 0   # one-token prompt: no hit


# ---------------------------------------------------------------------------
# radix index
# ---------------------------------------------------------------------------


def test_index_walk_extend_subtree():
    idx = PrefixIndex(BL)
    toks = list(range(12))
    created = idx.extend(toks, [10, 11, 12])
    assert [n.block for n in created] == [10, 11, 12]
    assert len(idx) == 3
    # longest-match walk, block-granular
    assert [n.block for n in idx.walk(toks)] == [10, 11, 12]
    assert [n.block for n in idx.walk(toks[:7])] == [10]
    assert idx.walk([99] * 8) == []
    # divergent suffix shares the common head node
    other = toks[:4] + [50, 51, 52, 53]
    created = idx.extend(other, [10, 33])
    assert [n.block for n in created] == [33]
    assert len(idx) == 4
    assert chunk_key(other, 1, BL) == (50, 51, 52, 53)
    # interior nodes cannot be removed; subtree order is leaves-first
    root_node = idx.walk(toks[:4])[0]
    with pytest.raises(KVStoreError):
        idx.remove(root_node)
    sub = idx.subtree(root_node)
    assert sub[-1] is root_node and len(sub) == 4


def test_cache_insert_hit_and_lru_eviction():
    led = _ledger()
    cache = PrefixCache(led, capacity_blocks=3)
    led.alloc(1, lines=12)
    t1 = list(range(100, 112))
    cache.insert(t1, led.tables[1])
    assert cache.cached_blocks() == 3
    assert all(led.refcount(b) == 2 for b in led.tables[1])
    # hit: peek has no side effects, lookup_pin counts + pins
    assert cache.peek_blocks(t1[:8]) == led.tables[1][:2]
    assert cache.stats["hits"] == 0
    run = cache.lookup_pin(rid=2, tokens=t1[:8])
    assert run == led.tables[1][:2]
    assert cache.stats == {"lookups": 1, "hits": 1, "hit_blocks": 2,
                           "hit_tokens": 8, "inserted_blocks": 3,
                           "evicted_blocks": 0}
    # capacity pressure: inserting a second prefix LRU-evicts unpinned
    # leaves, never the pinned run
    led.alloc(3, lines=8)
    t3 = list(range(200, 208))
    cache.insert(t3, led.tables[3])
    assert cache.cached_blocks() == 3
    assert set(run) <= set(cache.index.blocks())
    cache.unpin(2)
    assert not cache.pinned()


def test_free_returns_only_unique_blocks():
    """Satellite: share-aware free counts.  A shared-prefix request's
    release only reclaims its unique suffix; the last referent reclaims
    the head."""
    led = _ledger()
    cache = PrefixCache(led)
    led.alloc(1, lines=12)                       # 3 blocks
    head = led.tables[1][:2]
    cache.insert(list(range(12)), led.tables[1])  # refs: 2,2,2
    assert led.free(1) == 0                      # cache still holds all 3
    led.alloc(2, lines=12, shared=head)          # adopts 2, allocs 1
    assert led.shared_head_lines(2) == 8
    assert led.shared_blocks_count() == 2        # the adopted head blocks
    assert led.shared_saved_blocks() == 2
    assert led.free(2) == 1, "only the unique suffix block returns"
    assert cache.release_all() == 3              # last referent frees head
    assert led.free_blocks() == led.num_blocks
    with pytest.raises(KVStoreError):
        led.release(head)                        # double-free refused


def test_ledger_cow_on_shared_tail_append():
    led = _ledger()
    led.alloc(1, lines=6)                        # blocks A,B; B half full
    a, b = led.tables[1]
    led.retain([a, b])                           # external holder
    led.alloc(2, lines=6, shared=[a, b])         # adversarial: unaligned
    assert led.shared_head_lines(2) == 6
    assert led.append_line(2) == 7               # writes into shared B
    assert led.last_cow is not None
    rid, old_b, repl = led.last_cow
    assert (rid, old_b) == (2, b) and repl != b
    assert led.tables[2] == [a, repl]
    assert led.refcount(b) == 2                  # rid 1 + the retain
    assert led.shared_head_lines(2) == 4, "COW clamps the shared head"
    # rid 1's own tail is also shared (the retain): appending COWs too,
    # leaving the original bytes to the external holder alone
    led.append_line(1, 3)
    assert led.last_cow is not None and led.last_cow[:2] == (1, b)
    assert led.refcount(b) == 1                  # only the retain remains
    assert led.free(2) == 1                      # repl only; A still shared
    assert led.free(1) == 2                      # its COW copy + 3rd block
    assert led.release([a, b]) == 2


def test_evict_obstructing_spares_pinned_subtrees():
    led = _ledger()
    cache = PrefixCache(led)
    led.alloc(1, lines=16)
    toks = list(range(16))
    cache.insert(toks, led.tables[1])
    led.free(1)
    first, second = cache.index.blocks()[0], cache.index.blocks()[1]
    cache.lookup_pin(rid=9, tokens=toks[:4])     # pins `first`
    assert cache.evict_obstructing({first}) == 0, \
        "a pinned block anchors its whole subtree"
    assert cache.cached_blocks() == 4
    # an unpinned interior block takes its descendants with it
    assert cache.evict_obstructing({second}) == 3
    assert cache.cached_blocks() == 1
    cache.unpin(9)
    assert cache.release_all() == 1


# ---------------------------------------------------------------------------
# property: no leak, no double-free, refcounts == references (satellite)
# ---------------------------------------------------------------------------


def _check_conservation(led: BlockLedger, cache: PrefixCache):
    refs = {}
    for table in led.tables.values():
        for b in table:
            refs[b] = refs.get(b, 0) + 1
    for fb in led.fixed_block.values():
        if fb is not None:
            refs[fb] = refs.get(fb, 0) + 1
    for node in cache.index._nodes:
        refs[node.block] = refs.get(node.block, 0) + 1
    assert refs == led._refs, "refcounts drifted from actual references"
    assert len(set(led._free)) == len(led._free), "double-freed block"
    assert set(led._free).isdisjoint(led._refs)
    assert len(led._free) + len(led._refs) == led.num_blocks, "leaked block"


def _key_of(group: int, lines: int):
    return [(group, j) for j in range(lines)]


@given(st.booleans(),
       st.lists(st.tuples(st.integers(min_value=0, max_value=5),
                          st.integers(min_value=0, max_value=11),
                          st.integers(min_value=1, max_value=23)),
                min_size=1, max_size=60))
@settings(max_examples=30, deadline=None)
def test_refcount_invariant_under_random_interleavings(with_fixed, ops):
    """Random alloc/share/append(+COW)/free/insert/evict schedules: the
    per-block refcount must always equal the number of table references
    plus cache references, with no block leaked or double-freed, and a
    full teardown must return the entire pool."""
    led = _ledger(num_blocks=32, fixed=100 if with_fixed else 0)
    cache = PrefixCache(led, capacity_blocks=10)
    next_rid, live = 0, {}                        # rid -> group
    for kind, a, b in ops:
        if kind in (0, 1):                        # alloc (1: via cache hit)
            g, lines = a % 3, b
            run = cache.peek_blocks(_key_of(g, lines)) if kind == 1 else []
            run = run[:led.line_blocks_for(lines)]
            need = (led.line_blocks_for(lines) - len(run)
                    + (1 if led.costs.fixed_bytes > 0 else 0))
            if need <= led.free_blocks():
                led.alloc(next_rid, lines, shared=run or None)
                live[next_rid] = g
                next_rid += 1
        elif kind == 2 and live:                  # append (may COW)
            rid = sorted(live)[a % len(live)]
            old, table = led.lines(rid), led.tables[rid]
            cow = 1 if (old % BL and table
                        and led.refcount(table[-1]) > 1) else 0
            grow = led.line_blocks_for(old + 1) - len(table)
            if cow + max(grow, 0) <= led.free_blocks():
                led.append_line(rid)
        elif kind == 3 and live:                  # cache the aligned head
            rid = sorted(live)[a % len(live)]
            k = led.lines(rid) // BL
            if k:
                cache.insert(_key_of(live[rid], k * BL),
                             led.tables[rid][:k])
        elif kind == 4 and live:                  # free a request
            rid = sorted(live)[a % len(live)]
            table_len = len(led.tables[rid]) + (
                1 if led.fixed_block[rid] is not None else 0)
            freed = led.free(rid)
            del live[rid]
            assert 0 <= freed <= table_len
        elif kind == 5:                           # eviction pressure
            if b % 2:
                cache.evict_obstructing({b % 32})
            else:
                cache._evict_to(b % 8)
        _check_conservation(led, cache)
    for rid in list(live):
        led.free(rid)
        _check_conservation(led, cache)
    cache.release_all()
    assert led.free_blocks() == led.num_blocks, "teardown leaked blocks"


# ---------------------------------------------------------------------------
# golden lockstep trace: identical decisions AND identical hit accounting
# ---------------------------------------------------------------------------

_BLK = 8
# (prompt_len, decode_len, prefix_id, prefix_len) per arrival; pid None
# means a unique prompt.  Groups repeat so later arrivals hit.
_PTRACE = [("arrive", 24, 4, 0, 24), ("tick",),
           ("arrive", 24, 5, 0, 24), ("arrive", 18, 4, None, 0), ("tick",),
           ("arrive", 25, 3, 0, 24), ("arrive", 20, 6, 1, 16), ("tick",),
           ("arrive", 20, 4, 1, 16), ("tick",), ("tick",)]


def _group_tokens(cfg, key):
    out = {}
    for _, _, _, pid, pflen in (op for op in _PTRACE if op[0] == "arrive"):
        if pid is not None and pid not in out:
            out[pid] = jax.random.randint(
                jax.random.fold_in(key, 1000 + pid), (1, 32), 0,
                cfg.vocab_size)
    return out


def _hit_stats(cache):
    return {k: cache.stats[k]
            for k in ("lookups", "hits", "hit_blocks", "hit_tokens")}


def _run_live_prefix_trace(cfg, params, kernel):
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=8,
                          kv_capacity=256, policy=kernel, block_lines=_BLK,
                          prefix_cache=True)
    key = jax.random.PRNGKey(7)
    gtoks = _group_tokens(cfg, key)
    rids, saved = [], []
    for i, op in enumerate(_PTRACE):
        if op[0] == "arrive":
            _, plen, dlen, pid, pflen = op
            toks = jax.random.randint(jax.random.fold_in(key, i),
                                      (1, plen), 0, cfg.vocab_size)
            if pid is not None:
                toks = toks.at[0, :pflen].set(gtoks[pid][0, :pflen])
            req = Request(prompt_len=plen, max_new_tokens=dlen,
                          prompt_tokens=toks, prefix_id=pid,
                          prefix_len=pflen)
            rids.append(req.rid)
            cluster.submit(req)
        cluster.step()
        saved.append(tuple(e.store.ledger.shared_saved_blocks()
                           for e in cluster.engines))
    steps = 0
    while cluster.pending() and steps < 50:
        cluster.step()
        steps += 1
    assert not cluster.pending()
    stats = [_hit_stats(e.prefix_cache) for e in cluster.engines]
    return rids, steps, stats, saved, cluster.stats["prefix_hits"]


def _run_sim_prefix_trace(cfg, rids, extra_ticks):
    kernel = AcceLLMScheduler()
    kernel.trace = []
    perf = PerfModel(cfg, InstanceSpec(H100, 4))
    sim = Simulator(AcceLLMPolicy(kernel=kernel), perf, n_instances=2,
                    block_lines=_BLK, prefix_cache=True)
    sim.kick = lambda inst: None
    pol = sim.policy

    def tick(skip_iid=None):
        finished = {}
        for inst in sim.instances:
            if inst.iid == skip_iid:
                continue
            done_here = []
            for rid, r in list(inst.decode_batch.items()):
                r.generated += 1
                if r.done:
                    del inst.decode_batch[rid]
                    done_here.append(r)
            finished[inst.iid] = done_here
        for inst in sim.instances:
            if inst.iid in finished:
                pol.on_decode_done(inst, finished[inst.iid])

    arrivals = iter(rids)
    saved = []
    for op in _PTRACE:
        skip = None
        if op[0] == "arrive":
            _, plen, dlen, pid, pflen = op
            r = SimRequest(rid=next(arrivals), arrival=0.0,
                           prompt_len=plen, decode_len=dlen)
            r.prefix_id, r.prefix_len = pid, pflen
            inst = pol.route(r)
            pol._prefix_stamp(inst, r)      # the Prefill-creation stamp
            r.generated = 1                 # the prefill's first token
            pol.on_prefill_done(inst, [r])
            skip = inst.iid
        tick(skip_iid=skip)
        saved.append(tuple(i.synced_store().ledger.shared_saved_blocks()
                           for i in sim.instances))
    for _ in range(extra_ticks):
        tick()
    stats = [_hit_stats(i.prefix_cache) for i in sim.instances]
    return kernel.trace, stats, saved


def test_golden_prefix_trace_live_vs_sim(setup):
    """Under prefix-heavy traffic the two backends must agree on every
    kernel decision, on every cache's hit accounting, and — tick for
    tick — on the pool blocks saved by sharing."""
    cfg, params = setup
    live_kernel = AcceLLMScheduler()
    live_kernel.trace = []
    rids, extra, live_stats, live_saved, hits = \
        _run_live_prefix_trace(cfg, params, live_kernel)
    sim_trace, sim_stats, sim_saved = _run_sim_prefix_trace(cfg, rids, extra)
    assert live_kernel.trace == sim_trace, (
        "shared kernel diverged under prefix traffic:\n"
        f"live: {live_kernel.trace}\nsim:  {sim_trace}")
    assert live_stats == sim_stats, (
        "prefix-hit accounting diverged:\n"
        f"live: {live_stats}\nsim:  {sim_stats}")
    assert hits == sum(s["hits"] for s in live_stats) > 0, \
        "trace exercised no prefix hits"
    assert live_saved == sim_saved, (
        "shared-block dedup accounting diverged per tick:\n"
        f"live: {live_saved}\nsim:  {sim_saved}")
    assert any(s > 0 for tick_ in live_saved for s in tick_), \
        "sharing never materialized in the ledgers"


# ---------------------------------------------------------------------------
# live open loop: token bit-parity + ledger conservation (satellite)
# ---------------------------------------------------------------------------


def _reuse_spec():
    return WorkloadSpec(
        arrival=Poisson(rate=0.6, duration=14.0),
        lengths=UniformLengths(prompt=(10, 16), decode=(3, 6)),
        name="prefix-heavy",
        prefix_reuse=PrefixReuse(pool=2, reuse=0.8, prefix_len=8))


def _run_live(cfg, params, prefix_cache: bool):
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=4,
                          kv_capacity=64, policy=AcceLLMScheduler(),
                          block_lines=_BLK, prefix_cache=prefix_cache)
    done = cluster.run(max_steps=300,
                       source=_reuse_spec().source(seed=3, cfg=cfg))
    return cluster, done


def test_live_tokens_bit_identical_with_cache_on(setup):
    cfg, params = setup
    off_cluster, off = _run_live(cfg, params, prefix_cache=False)
    on_cluster, on = _run_live(cfg, params, prefix_cache=True)
    assert off_cluster.stats["prefix_hits"] == 0
    assert on_cluster.stats["prefix_hits"] > 0, \
        "reuse traffic produced no hits"
    assert on_cluster.stats["prefix_hit_tokens"] > 0
    toks_off = {r.rid: r.output_tokens for r in off}
    toks_on = {r.rid: r.output_tokens for r in on}
    assert toks_off.keys() == toks_on.keys()
    assert toks_off == toks_on, \
        "prefix-cache adoption changed a generated token"


def test_live_batch_arrival_never_overcommits_slots(setup):
    """Regression: stamping a hit pins the cached run, which can wall
    off the slot region holding it — ``free_slots`` shrinks between the
    policy's admission count and execution.  A batch arrival of more
    requests than slots under heavy reuse used to trip the no-free-slot
    assert in ``_take_slot``; admission must re-count capacity per
    request (and abandon a stamp that froze the last free slot)."""
    cfg, params = setup
    spec = WorkloadSpec(
        arrival=Batch(n=12),
        lengths=UniformLengths(prompt=(10, 16), decode=(3, 6)),
        name="thundering-herd",
        prefix_reuse=PrefixReuse(pool=2, reuse=0.8, prefix_len=8))
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=3,
                          kv_capacity=64, policy=AcceLLMScheduler(),
                          block_lines=_BLK, prefix_cache=True)
    done = cluster.run(max_steps=400, source=spec.source(seed=3, cfg=cfg))
    assert len(done) == 12, "batch arrival did not drain"
    assert cluster.stats["prefix_hits"] > 0, \
        "reuse batch produced no hits"


def test_live_ledger_conservation_under_reuse(setup):
    """Per scheduling iteration, every engine's pool must conserve:
    distinct used blocks == table references + cache references − the
    blocks sharing saved, and used-bytes stay the line-exact identity
    (sharing dedups BLOCKS, never changes a request's line count)."""
    cfg, params = setup
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=4,
                          kv_capacity=64, policy=AcceLLMScheduler(),
                          block_lines=_BLK, prefix_cache=True)
    source = iter(_reuse_spec().source(seed=3, cfg=cfg))
    pending = next(source, None)
    saw_sharing = False
    for _ in range(300):
        while pending is not None and pending.arrival <= cluster.now:
            cluster.submit(pending, stamp_arrival=False)
            pending = next(source, None)
        if pending is None and not cluster.pending():
            break
        cluster.step()
        for eng in cluster.engines:
            led = eng.store.ledger
            table_refs = sum(len(t) for t in led.tables.values()) + sum(
                1 for fb in led.fixed_block.values() if fb is not None)
            cache_refs = eng.prefix_cache.cached_blocks()
            assert led.used_blocks() == (table_refs + cache_refs
                                         - led.shared_saved_blocks())
            assert led.free_blocks() + led.used_blocks() == led.num_blocks
            assert led.used_bytes() == pytest.approx(sum(
                led.costs.bytes_at(n) for n in led._lines.values()))
            if led.shared_saved_blocks():
                saw_sharing = True
    assert not cluster.pending(), "trace did not drain"
    assert saw_sharing, "no block was ever shared"


def test_sim_prefix_run_drains_and_conserves(setup):
    cfg, _ = setup
    sim = Simulator(AcceLLMPolicy(), PerfModel(cfg, InstanceSpec(H100, 4)),
                    n_instances=2, block_lines=_BLK, prefix_cache=True)
    done = sim.run(source=_reuse_spec().source(seed=3), horizon=200.0)
    assert len(done) == len(sim.submitted)
    hits = sum(i.prefix_cache.stats["hits"] for i in sim.instances
               if i.prefix_cache is not None)
    assert hits > 0
    for inst in sim.instances:
        led = inst.synced_store().ledger
        # drained: only cache references remain, one per cached block
        assert set(led._refs) == set(inst.prefix_cache.index.blocks())
        assert all(c == 1 for c in led._refs.values())


# ---------------------------------------------------------------------------
# workload: the reuse knob keeps the stream backend- and cache-agnostic
# ---------------------------------------------------------------------------


def test_prefix_reuse_stream_is_shared_and_deterministic(setup):
    cfg, _ = setup
    spec = _reuse_spec()
    live = list(spec.source(seed=5, cfg=cfg))
    sim = list(spec.source(seed=5))
    assert [(r.rid, r.arrival, r.prompt_len, r.prefix_id, r.prefix_len)
            for r in live] == \
        [(r.rid, r.arrival, r.prompt_len, r.prefix_id, r.prefix_len)
         for r in sim]
    by_group = {}
    for r in live:
        if r.prefix_id is not None:
            by_group.setdefault(r.prefix_id, []).append(r)
    assert any(len(v) >= 2 for v in by_group.values()), \
        "reuse=0.8 must repeat a group"
    for members in by_group.values():
        head = np.asarray(members[0].prompt_tokens)[0]
        for r in members[1:]:
            n = min(members[0].prefix_len, r.prefix_len)
            assert np.array_equal(np.asarray(r.prompt_tokens)[0, :n],
                                  head[:n]), \
                "group members must share their declared head tokens"
        for r in members:
            assert r.prefix_len <= r.prompt_len


def test_prefix_reuse_growth_caps():
    pr = PrefixReuse(pool=1, reuse=1.0, prefix_len=8, growth=4,
                     max_prefix=16)
    spec = WorkloadSpec(arrival=Poisson(rate=2.0, duration=10.0),
                        lengths=UniformLengths(prompt=(40, 48),
                                               decode=(1, 2)),
                        prefix_reuse=pr)
    declared = [r.prefix_len for r in spec.source(seed=0)]
    assert len(declared) >= 4
    assert declared[0] == 8, "first draw uses the base prefix length"
    assert max(declared) <= pr.cap == 16, "growth must cap at max_prefix"
    assert declared[-1] == 16, "history accretes across draws"
