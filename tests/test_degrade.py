"""Graceful degradation layer: straggler hedging via redundancy,
deadline-aware admission control, and the request abort lifecycle.

The load-bearing check mirrors test_fleet's golden trace: the same
arrival script with a mid-serve degrade, an abort and a queue-full shed
must produce the IDENTICAL kernel trace (route/place/hedge) AND the
identical fleet-controller trace (degrade/abort/shed/recover) with the
identical counters whether the events hit the live-engine executor or
the simulator adapter — and neither backend may leak a single ledger
block for a shed or aborted request.
"""
import heapq

import jax
import pytest
from _propcheck import given, settings, st

from repro.configs import get_config
from repro.fleet import (DegradeInstance, FixedFleet, FleetController,
                         JoinInstance, KillInstance, PoissonDegradations,
                         RecoverInstance, load_fleet_trace, save_fleet_trace)
from repro.models import init_params
from repro.scheduling import AcceLLMScheduler, LiveCluster
from repro.scheduling.registry import get_policy
from repro.scheduling.views import HEALTH_ALPHA, step_health
from repro.serving import Request
from repro.serving.request import Phase
from repro.sim import (H100, AcceLLMPolicy, InstanceSpec, PerfModel,
                       Simulator, SimRequest)
from repro.workloads import SLO, Bursty, TableLengths, WorkloadSpec, \
    slo_summary


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _perf(cfg=None):
    return PerfModel(cfg or get_config("llama2-70b"), InstanceSpec(H100, 4))


# ---------------------------------------------------------------------------
# schedules: seeded degradation streams + JSONL round-trip
# ---------------------------------------------------------------------------


def test_poisson_degradations_seeded_and_bounded():
    sched = PoissonDegradations(mtbf=5.0, duration=100.0, n_instances=4,
                                recovery=3.0, factor=6.0)
    a, b = sched.stream(seed=0), sched.stream(seed=0)
    assert a == b, "same seed must replay the identical straggler stream"
    assert a != sched.stream(seed=1)
    degrades = [e for e in a if isinstance(e, DegradeInstance)]
    recovers = [e for e in a if isinstance(e, RecoverInstance)]
    assert degrades, "mtbf=5 over 100 units must produce stragglers"
    assert all(0.0 < e.t < 100.0 for e in degrades)
    assert all(0 <= e.instance < 4 for e in degrades)
    assert all(e.factor == 6.0 for e in degrades)
    # each degrade is followed by a recovery of the same instance
    assert len(recovers) == len(degrades)
    assert [e.t for e in a] == sorted(e.t for e in a), "stream() sorts"
    # no recovery -> permanent stragglers
    dark = PoissonDegradations(mtbf=5.0, duration=100.0, n_instances=4)
    assert all(isinstance(e, DegradeInstance) for e in dark.stream(seed=0))


def test_degrade_trace_jsonl_round_trip(tmp_path):
    events = [DegradeInstance(1.5, 2, 3.0, 2.0), KillInstance(2.0, 1),
              RecoverInstance(4.0, 2), JoinInstance(5.0, 1)]
    path = tmp_path / "fleet.jsonl"
    assert save_fleet_trace(path, events) == 4
    loaded = load_fleet_trace(path)
    assert loaded.stream(seed=0) == events, \
        "factor/link_factor must round-trip through JSONL"


# ---------------------------------------------------------------------------
# health EWMA: the shared arithmetic both executors call
# ---------------------------------------------------------------------------


def test_step_health_identity_and_decay():
    # nominal speed is a fixed point
    assert step_health(1.0, 1.0) == 1.0
    # one degraded iteration at the default factor crosses the default
    # hedge threshold (1.5) immediately
    h = step_health(1.0, 4.0)
    assert h == 1.0 + HEALTH_ALPHA * 3.0 == 2.5
    assert h >= AcceLLMScheduler().hedge_threshold
    # recovery decays it back under the threshold within two iterations
    h = step_health(h, 1.0)
    assert h == 1.75
    h = step_health(h, 1.0)
    assert h == 1.375 < AcceLLMScheduler().hedge_threshold


def test_live_health_tracks_degrade_and_recover(setup):
    cfg, params = setup
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=4,
                          kv_capacity=128, policy=AcceLLMScheduler())
    cluster.fleet_degrade(0, factor=4.0, link_factor=2.0)
    assert cluster.degrade[0] == 4.0 and cluster.link_degrade[0] == 2.0
    cluster.step()
    assert cluster.health[0] == 2.5 and cluster.health[1] == 1.0
    cluster.fleet_recover(0)
    cluster.step()
    cluster.step()
    assert cluster.health[0] == 1.375
    trace = cluster.fleet.trace
    assert ("degrade", 0, 4.0, 2.0) in trace and ("recover", 0) in trace
    assert cluster.fleet.stats["degrades"] == 1
    assert cluster.fleet.stats["recoveries"] == 1
    # degrading a dead instance is a no-op, not a crash
    cluster.fleet_kill(1)
    cluster.fleet_degrade(1)
    assert cluster.degrade[1] == 1.0


def test_sim_health_tracks_degrade_through_event_loop():
    reqs = [SimRequest(rid=i, arrival=0.0, prompt_len=16, decode_len=64)
            for i in range(4)]
    fleet = FleetController(FixedFleet((DegradeInstance(0.05, 0, 4.0),)))
    sim = Simulator(AcceLLMPolicy(), _perf(), n_instances=2)
    sim.run(requests=reqs, horizon=600.0, fleet=fleet)
    assert fleet.stats["degrades"] == 1
    assert sim.instances[0].health > 1.5, \
        "the degraded instance's EWMA must track its slowdown"
    assert sim.instances[1].health == 1.0
    assert len(sim.finished) == 4


# ---------------------------------------------------------------------------
# satellite: pair-count validation raises, not asserts
# ---------------------------------------------------------------------------


def test_odd_instances_raise_value_error(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="pairs"):
        LiveCluster(cfg, params, n_instances=3, num_slots=4,
                    kv_capacity=128, policy=AcceLLMScheduler())
    with pytest.raises(ValueError, match="pairs"):
        Simulator(AcceLLMPolicy(), _perf(), n_instances=3)


def test_config_validation_raises_value_error():
    import dataclasses
    base = get_config("starcoder2-3b")
    with pytest.raises(ValueError, match="block_pattern"):
        dataclasses.replace(base, block_pattern=("attn",) * (base.num_layers
                                                             + 1))
    with pytest.raises(ValueError, match="divisible"):
        dataclasses.replace(base, num_heads=5, num_kv_heads=2, head_dim=16)
    with pytest.raises(ValueError, match="unknown block kind"):
        dataclasses.replace(base,
                            block_pattern=("nope",) * base.num_layers)


# ---------------------------------------------------------------------------
# golden degrade trace: live executor vs simulator adapter, same script
# ---------------------------------------------------------------------------

# arrivals keep both pair sides loaded; a degrade turns instance 0 into a
# straggler (hedge flips its primaries to their mirrors on instance 1), a
# decoding request is aborted mid-flight, the straggler recovers, then a
# burst against the bounded queue sheds exactly one arrival at the door
_CHAOS_SCRIPT = [
    ("arrive", 8, 14), ("tick",),
    ("arrive", 10, 14), ("tick",),
    ("arrive", 6, 12), ("tick",),
    ("tick",),
    ("degrade", 0, 4.0),
    ("tick",),              # health[0] -> 2.5: hedge fires this iteration
    ("tick",),
    ("abort", 1),           # cancel a decoding request mid-flight
    ("tick",),
    ("recover", 0),
    ("tick",), ("tick",),   # health decays back under the threshold
    ("arrive", 7, 6), ("arrive", 9, 6), ("arrive", 6, 6),  # third one sheds
    ("tick",), ("tick",),
]
_MAX_QUEUE = 2


def _run_live_chaos(cfg, params, kernel, script):
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=8,
                          kv_capacity=256, policy=kernel,
                          max_queue=_MAX_QUEUE)
    key = jax.random.PRNGKey(7)
    rids, reqs = [], []
    for i, op in enumerate(script):
        if op[0] == "arrive":
            plen, dlen = op[1], op[2]
            req = Request(prompt_len=plen, max_new_tokens=dlen,
                          prompt_tokens=jax.random.randint(
                              jax.random.fold_in(key, i), (1, plen), 0,
                              cfg.vocab_size))
            rids.append(req.rid)
            reqs.append(req)
            cluster.submit(req)
        elif op[0] == "degrade":
            cluster.fleet_degrade(op[1], op[2])
        elif op[0] == "recover":
            cluster.fleet_recover(op[1])
        elif op[0] == "abort":
            cluster.abort(rids[op[1]])
        elif op[0] == "tick":
            cluster.step()
    steps = 0
    while cluster.pending() and steps < 200:
        cluster.step()
        steps += 1
    assert not cluster.pending()
    return cluster, rids, reqs, steps


def _run_sim_chaos(cfg, rids, extra_steps, script):
    """Lock-step simulator drive of the same script (the test_fleet
    harness plus degradation ops): the health EWMA advances once per
    step for every alive instance — the live executor's cadence — and
    sheds/aborts note into the same controller."""
    kernel = AcceLLMScheduler()
    kernel.trace = []
    sim = Simulator(AcceLLMPolicy(kernel=kernel), _perf(cfg), n_instances=2)
    sim.kick = lambda inst: None          # event mechanics not under test
    pol = sim.policy
    ctrl = FleetController()
    sim.fleet = ctrl                      # sheds/hedges count here

    def tick(skip_iid=None):
        finished = {}
        for inst in sim.instances:
            if not inst.alive or inst.iid == skip_iid:
                continue
            done_here = []
            for rid, r in list(inst.decode_batch.items()):
                r.generated += 1
                if r.done:
                    del inst.decode_batch[rid]
                    done_here.append(r)
            finished[inst.iid] = done_here
        for inst in sim.instances:
            if inst.iid in finished:
                pol.on_decode_done(inst, finished[inst.iid])

    queue = []

    def step_once():
        # live updates every alive instance's health at the top of step()
        for inst in sim.instances:
            if inst.alive:
                inst.health = step_health(inst.health, inst.degrade_factor)
        skip = None
        if queue:                          # admissions_per_step == 1
            r = queue[0]
            inst = pol.route(r)
            if inst is not None:
                queue.pop(0)
                r.generated = 1            # the prefill's first token
                pol.on_prefill_done(inst, [r])
                skip = inst.iid
        tick(skip_iid=skip)

    arrivals = iter(rids)
    for op in script:
        if op[0] == "arrive":
            r = SimRequest(rid=next(arrivals), arrival=0.0,
                           prompt_len=op[1], decode_len=op[2])
            if len(queue) >= _MAX_QUEUE:   # the live door check
                sim._shed(r)
                continue
            queue.append(r)
        elif op[0] == "degrade":
            pol._fleet_degrade(op[1], op[2], 1.0, ctrl)
        elif op[0] == "recover":
            pol._fleet_recover(op[1], ctrl)
        elif op[0] == "abort":
            rid = rids[op[1]]
            held = [r for r in queue if r.rid == rid]
            if held:
                queue.remove(held[0])
                held[0].phase = Phase.ABORTED
                sim.aborted.append(held[0])
                ctrl.note("abort", rid)
                ctrl.stats["aborts"] += 1
            else:
                sim.abort(rid)
        if op[0] == "tick":          # the live harness only steps on ticks
            step_once()
    for _ in range(extra_steps):
        step_once()
    return kernel.trace, ctrl, sim


def test_golden_degrade_trace_live_vs_sim(setup):
    cfg, params = setup
    live_kernel = AcceLLMScheduler()
    live_kernel.trace = []
    cluster, rids, reqs, extra = _run_live_chaos(cfg, params, live_kernel,
                                                 _CHAOS_SCRIPT)
    sim_trace, sim_ctrl, sim = _run_sim_chaos(cfg, rids, extra,
                                              _CHAOS_SCRIPT)

    assert live_kernel.trace == sim_trace, (
        "shared kernel diverged across backends under degradation:\n"
        f"live: {live_kernel.trace}\nsim:  {sim_trace}")
    live_ctrl = cluster.fleet
    assert live_ctrl.trace == sim_ctrl.trace, (
        "degradation lifecycle diverged:\n"
        f"live: {live_ctrl.trace}\nsim:  {sim_ctrl.trace}")
    assert live_ctrl.stats == sim_ctrl.stats

    # the script's events all fired, on both backends identically
    assert live_ctrl.stats["degrades"] == 1
    assert live_ctrl.stats["recoveries"] == 1
    assert live_ctrl.stats["aborts"] == 1
    assert live_ctrl.stats["sheds"] == 1
    assert live_ctrl.stats["hedges"] > 0, \
        "the degraded side's primaries must hedge to their mirrors"
    assert "hedge" in {e[0] for e in live_kernel.trace}

    # terminal accounting: every submitted request is finished, shed or
    # aborted — and the outcomes agree with the script
    aborted_rid = rids[1]
    assert [r.rid for r in cluster.aborted] == [aborted_rid]
    assert len(cluster.shed) == 1
    n_terminal = 0
    for r in reqs:
        if r.phase in (Phase.SHED, Phase.ABORTED):
            n_terminal += 1
            continue
        assert len(r.output_tokens) == r.max_new_tokens
        n_terminal += 1
    assert n_terminal == len(reqs)
    assert {r.rid for r in sim.aborted} == {aborted_rid}
    assert len(sim.shed) == 1

    # zero leaked ledger blocks after the aborts, on both backends
    for eng in cluster.engines:
        assert aborted_rid not in eng.store.ledger.tables
        assert eng.store.ledger.used_blocks() == 0
    for inst in sim.instances:
        led = inst.synced_store().ledger
        assert aborted_rid not in led.tables
        assert led.used_blocks() == 0
    assert aborted_rid not in cluster.placements
    assert aborted_rid not in sim.policy.placement


# ---------------------------------------------------------------------------
# satellite: vec kernels + array state stay coherent through chaos
# ---------------------------------------------------------------------------

_CHAOS_FLEET = FixedFleet((
    DegradeInstance(4.0, 1, 4.0), KillInstance(10.0, 2),
    RecoverInstance(14.0, 1), JoinInstance(18.0, 2),
    DegradeInstance(22.0, 0, 3.0), RecoverInstance(30.0, 0),
))

_CHAOS_SPEC = WorkloadSpec(
    arrival=Bursty(rate_on=12.0, duration=40.0, rate_off=2.0,
                   mean_on=6.0, mean_off=4.0),
    lengths=TableLengths(workload="mixed"), name="bursty")


def _run_chaos_traced(policy, max_queue=None):
    policy.kernel.trace = []
    sim = Simulator(policy, _perf(), n_instances=4, max_queue=max_queue)
    ctrl = FleetController(_CHAOS_FLEET)
    sim.run(source=_CHAOS_SPEC.source(seed=0), horizon=500.0, fleet=ctrl)
    return policy.kernel.trace, sim, ctrl


def test_vec_scalar_coherent_across_kill_join_degrade():
    """Satellite regression: the array-backed kernel must make the
    identical decisions through an interleaved kill -> join -> degrade
    chaos run — membership arrays, replica arrays AND the health vector
    all have to stay coherent with the dict state."""
    tr_s, sim_s, ctrl_s = _run_chaos_traced(AcceLLMPolicy())
    tr_v, sim_v, ctrl_v = _run_chaos_traced(
        AcceLLMPolicy(kernel=get_policy("accellm-vec")))
    assert len(tr_s) > 50, "trace must exercise real scheduling"
    assert tr_s == tr_v, (
        "vectorized kernel diverged from dict-backed under chaos at entry "
        f"{next((i for i, (a, b) in enumerate(zip(tr_s, tr_v)) if a != b), 'len')}")
    assert ctrl_s.trace == ctrl_v.trace
    assert ctrl_s.stats == ctrl_v.stats
    assert ctrl_s.stats["degrades"] == 2 and ctrl_s.stats["kills"] == 1
    fp = lambda sim: [(r.rid, r.generated, r.finish_time)
                      for r in sorted(sim.submitted, key=lambda r: r.rid)]
    assert fp(sim_s) == fp(sim_v)
    # the array state's health vector mirrors the instances exactly
    arrays = sim_v.policy.arrays
    assert arrays is not None
    assert list(arrays.health_vec()) == [i.health for i in sim_v.instances]


# ---------------------------------------------------------------------------
# satellite: property test — chaos interleavings conserve the ledger
# ---------------------------------------------------------------------------

_OPS = st.lists(st.tuples(st.integers(min_value=0, max_value=99),
                          st.integers(min_value=0, max_value=31)),
                min_size=24, max_size=56)


@settings(max_examples=20, deadline=None)
@given(_OPS)
def test_random_chaos_interleavings_conserve_ledger(ops):
    """Random admit/abort/shed/degrade/kill/join interleavings must
    conserve the ledger invariant: every offered request ends in exactly
    one terminal or in-flight state, aborted rids vanish from every
    container, and after a full drain no instance holds a single block."""
    sim = Simulator(AcceLLMPolicy(), _perf(), n_instances=2, max_queue=4)
    sim.kick = lambda inst: None
    pol = sim.policy
    ctrl = FleetController()
    sim.fleet = ctrl
    issued = []
    aborted_rids = set()
    rid_seq = iter(range(10_000))

    def drain_requeues():
        while sim._heap:
            _, _, kind, data = heapq.heappop(sim._heap)
            if kind == "arrival":
                sim._handle_arrival(data)

    def tick():
        for inst in sim.instances:
            if not inst.alive:
                continue
            inst.health = step_health(inst.health, inst.degrade_factor)
            if inst.prefill_queue:
                r = inst.prefill_queue.pop(0)
                r.generated = 1
                pol.on_prefill_done(inst, [r])
                continue
            done = []
            for rid, r in list(inst.decode_batch.items()):
                r.generated += 1
                if r.done:
                    del inst.decode_batch[rid]
                    done.append(r)
                    r.finish_time = sim.now
                    sim.finished.append(r)
            pol.on_decode_done(inst, done)

    def check_invariants():
        for rid in aborted_rids:
            for inst in sim.instances:
                assert rid not in inst.decode_batch
                assert rid not in inst.replicas
                assert rid not in inst.synced_marks
                assert all(r.rid != rid for r in inst.prefill_queue)
            assert rid not in pol.placement
        # resident sets and ledgers agree (reconcile-on-read is exact)
        for inst in sim.instances:
            led = inst.synced_store().ledger
            assert set(led.tables) == (set(inst.decode_batch)
                                       | set(inst.replicas))
        resident = set()
        for inst in sim.instances:
            resident |= set(inst.decode_batch)
            resident |= {r.rid for r in inst.prefill_queue}
        terminal = (len(sim.finished) + len(sim.shed) + len(sim.aborted)
                    + len(sim.dropped))
        assert terminal + len(resident) == len(issued), \
            "a request leaked out of the lifecycle accounting"

    for kind, arg in ops:
        if kind < 40:                                   # arrive
            r = SimRequest(rid=next(rid_seq), arrival=sim.now,
                           prompt_len=8 + arg % 8, decode_len=4 + arg % 6)
            issued.append(r)
            sim._handle_arrival(r)
        elif kind < 70:                                 # tick
            tick()
        elif kind < 80 and issued:                      # abort
            victim = issued[arg % len(issued)]
            got = sim.abort(victim.rid)
            if got is not None:
                aborted_rids.add(victim.rid)
        elif kind < 86:                                 # degrade
            pol._fleet_degrade(arg % 2, 2.0 + arg % 4, 1.0, ctrl)
        elif kind < 90:                                 # recover
            pol._fleet_recover(arg % 2, ctrl)
        elif kind < 95:                                 # kill + requeue
            iid = arg % 2
            if sim.instances[iid].alive \
                    and any(i.alive for i in sim.instances if i.iid != iid):
                pol._fleet_kill(iid, ctrl)
                drain_requeues()
        else:                                           # join (revive)
            iid = arg % 2
            if not sim.instances[iid].alive:
                pol._fleet_join(iid, ctrl)
        check_invariants()

    for _ in range(400):
        if not any(i.decode_batch or i.prefill_queue
                   for i in sim.instances if i.alive):
            break
        tick()
    check_invariants()
    # after the drain every *alive* path is empty and no block leaks
    for inst in sim.instances:
        if inst.alive:
            assert not inst.decode_batch and not inst.prefill_queue
            assert inst.synced_store().ledger.used_blocks() == 0


# ---------------------------------------------------------------------------
# live executor: admission control + abort lifecycle units
# ---------------------------------------------------------------------------


def _live_req(cfg, i, plen, dlen, key):
    return Request(prompt_len=plen, max_new_tokens=dlen,
                   prompt_tokens=jax.random.randint(
                       jax.random.fold_in(key, i), (1, plen), 0,
                       cfg.vocab_size))


def test_live_max_queue_sheds_at_door(setup):
    cfg, params = setup
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=4,
                          kv_capacity=128, policy=AcceLLMScheduler(),
                          max_queue=2)
    key = jax.random.PRNGKey(3)
    reqs = [_live_req(cfg, i, 6 + i % 3, 4, key) for i in range(4)]
    for r in reqs:
        cluster.submit(r)
    assert len(cluster.shed) == 2, "arrivals 3 and 4 exceed the bound"
    assert all(r.phase is Phase.SHED for r in cluster.shed)
    assert cluster.stats["sheds"] == 2
    done = cluster.run(max_steps=80)
    assert len(done) == 2
    assert len(done) + len(cluster.shed) == len(cluster._submitted)
    # a shed rid may be resubmitted later (its terminal state is final)
    again = Request(prompt_len=6, max_new_tokens=3, rid=cluster.shed[0].rid,
                    prompt_tokens=jax.random.randint(
                        jax.random.fold_in(key, 9), (1, 6), 0,
                        cfg.vocab_size))
    cluster.submit(again)
    cluster.run(max_steps=60)
    assert len(again.output_tokens) == again.max_new_tokens


def test_live_shed_deadline_refuses_stale_queue(setup):
    cfg, params = setup
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=2,
                          kv_capacity=64, policy="vllm", shed_deadline=3.0)
    key = jax.random.PRNGKey(4)
    # more arrivals than the two-slot engines can start on time
    reqs = [_live_req(cfg, i, 6, 8, key) for i in range(8)]
    for r in reqs:
        cluster.submit(r)
    done = cluster.run(max_steps=200)
    assert cluster.shed, "an 8-deep backlog on 2 slots must blow a 3-iter " \
                         "deadline for someone"
    assert all(r.phase is Phase.SHED for r in cluster.shed)
    assert all(not r.output_tokens for r in cluster.shed), \
        "deadline sheds must never have consumed decode"
    assert len(done) + len(cluster.shed) == len(reqs)
    rep = slo_summary(cluster._submitted, SLO(ttft=3.0), duration=cluster.now,
                      unit="iters")
    assert rep.n_shed == len(cluster.shed)
    assert rep.n_submitted == len(reqs)
    assert rep.attainment < 1.0, "sheds count as SLO misses"
    assert "shed" in rep.describe()


def test_live_abort_mid_decode_frees_all_state(setup):
    cfg, params = setup
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=8,
                          kv_capacity=256, policy=AcceLLMScheduler())
    key = jax.random.PRNGKey(5)
    reqs = [_live_req(cfg, i, 8, 12, key) for i in range(2)]
    for r in reqs:
        cluster.submit(r)
    for _ in range(4):
        cluster.step()
    victim = reqs[0]
    assert victim.rid in cluster.placements, "victim must be decoding"
    pl = cluster.placements[victim.rid]
    assert pl.replica is not None, "redundancy must have mirrored it"
    got = cluster.abort(victim.rid)
    assert got is victim and victim.phase is Phase.ABORTED
    assert victim.rid not in cluster.placements
    for eng in cluster.engines:
        assert victim.rid not in eng.store.ledger.tables, \
            "abort must free primary AND replica blocks"
        assert all(r.rid != victim.rid for r in eng.slot_req.values())
    assert cluster.stats["aborts"] == 1
    # aborting the same rid again is a no-op, unknown rids return None
    assert cluster.abort(victim.rid) is None
    assert cluster.abort(99_999) is None
    assert cluster.stats["aborts"] == 1
    # the survivor is unaffected
    done = cluster.run(max_steps=80)
    assert reqs[1] in done
    assert len(reqs[1].output_tokens) == reqs[1].max_new_tokens


def test_live_abort_queued_request(setup):
    cfg, params = setup
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=4,
                          kv_capacity=128, policy=AcceLLMScheduler())
    key = jax.random.PRNGKey(6)
    reqs = [_live_req(cfg, i, 6, 4, key) for i in range(3)]
    for r in reqs:
        cluster.submit(r)
    got = cluster.abort(reqs[2].rid)     # still queued: nothing resident
    assert got is reqs[2] and got.phase is Phase.ABORTED
    done = cluster.run(max_steps=80)
    assert len(done) == 2 and reqs[2] not in done


def test_sim_run_sheds_and_aborts_end_to_end():
    reqs = [SimRequest(rid=i, arrival=0.0, prompt_len=24, decode_len=16)
            for i in range(40)]
    sim = Simulator(AcceLLMPolicy(), _perf(), n_instances=2,
                    max_queue=4, shed_deadline=2.0)
    sim.run(requests=reqs, horizon=600.0)
    assert sim.shed, "a 40-request burst against max_queue=4 must shed"
    assert all(r.phase is Phase.SHED for r in sim.shed)
    assert len(sim.finished) + len(sim.shed) + len(sim.dropped) == len(reqs)
    rep = slo_summary(sim.submitted, SLO(ttft=5.0, tbt=2.0),
                      duration=sim.now, unit="s")
    assert rep.n_shed == len(sim.shed)
    assert rep.n_submitted == len(reqs)
    # shed requests hold no blocks anywhere
    for inst in sim.instances:
        led = inst.synced_store().ledger
        for r in sim.shed:
            assert r.rid not in led.tables


def test_serve_report_counts_shed_and_aborted(setup):
    from repro.api import ServeSpec, serve
    cfg, params = setup
    spec = ServeSpec(arch="starcoder2-3b", policy="accellm", n_instances=2,
                     num_slots=4, kv_capacity=128, n_requests=6,
                     workload="light", max_steps=200, max_queue=2,
                     slo=SLO(ttft=20.0, tbt=4.0))
    report = serve(spec, cfg=cfg, params=params)
    assert report.n_shed > 0
    assert report.all_finished, \
        "shed requests are terminal: a degraded run still completes"
    assert report.n_unfinished == 0
    assert f"({report.n_shed} shed)" in report.describe()
    s = report.slo()
    assert s.n_shed == report.n_shed
    assert s.n_submitted == report.n_submitted
