"""Launcher spec rules: input ShapeDtypeStructs, param/state PartitionSpecs
(divisibility-checked), layout selection."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch.specs import (input_specs, param_pspecs, pick_layout,
                                state_pspecs, token_layout)
from repro.models import init_params, init_state


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_shapes(arch, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    sds, specs = input_specs(cfg, shape)
    assert set(sds) == set(specs)
    if shape.kind == "decode":
        assert sds["tokens"].shape == (shape.global_batch, 1)
        assert sds["t"].shape == (shape.global_batch,)
    else:
        B, S = sds["tokens"].shape
        assert B == shape.global_batch
        layout = token_layout(cfg, shape)
        assert S == layout["text_len"]
        if cfg.frontend is not None and cfg.frontend.kind == "vision":
            # patches + text == requested seq_len
            assert S + cfg.frontend.num_prefix_tokens == shape.seq_len


def test_param_pspecs_structure_and_divisibility():
    cfg = get_config("phi3-medium-14b")
    ps = jax.eval_shape(lambda k: init_params(k, cfg.reduced()),
                        jax.random.PRNGKey(0))
    specs = param_pspecs(cfg.reduced(), ps, mode="serve")
    # same treedef
    assert jax.tree_util.tree_structure(ps) == \
        jax.tree_util.tree_structure(specs)
    flat_p = jax.tree_util.tree_leaves(ps)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= len(leaf.shape)
        for dim, ax in enumerate(spec):
            if ax == "model":
                assert leaf.shape[dim] % 16 == 0, (
                    f"non-divisible shard: {leaf.shape} {spec}")


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "jamba-1.5-large-398b"])
def test_state_pspecs_decode(arch):
    cfg = get_config(arch)
    shape = INPUT_SHAPES["decode_32k"]
    st = jax.eval_shape(lambda: init_state(cfg.reduced(), shape.global_batch,
                                           256))
    specs = state_pspecs(cfg.reduced(), st, shape, long_context=False)
    assert jax.tree_util.tree_structure(st) == \
        jax.tree_util.tree_structure(specs)


def test_mla_latent_cache_sequence_sharded():
    """§Perf iteration 5: the MLA latent cache shards its seq dim on model."""
    cfg = get_config("deepseek-v3-671b")
    shape = INPUT_SHAPES["decode_32k"]
    st = jax.eval_shape(lambda: init_state(cfg, shape.global_batch,
                                           shape.seq_len))
    specs = state_pspecs(cfg, st, shape, long_context=False)

    found = []

    def walk(path, spec):
        found.append((jax.tree_util.keystr(path), spec))

    jax.tree_util.tree_map_with_path(
        walk, specs, is_leaf=lambda x: isinstance(x, P))
    ckv = [s for p, s in found if "c_kv" in p]
    assert ckv and all(s[2] == "model" for s in ckv)


def test_long_context_kv_data_sharded_for_hybrid():
    cfg = get_config("jamba-1.5-large-398b")
    shape = INPUT_SHAPES["long_500k"]
    st = jax.eval_shape(lambda: init_state(cfg, 1, shape.seq_len, True))
    specs = state_pspecs(cfg, st, shape, long_context=True)
    flat = []
    jax.tree_util.tree_map_with_path(
        lambda p, s: flat.append((jax.tree_util.keystr(p), s)), specs,
        is_leaf=lambda x: isinstance(x, P))
    ks = [s for p, s in flat if p.endswith("['k']")]
    assert ks and all(s[2] in ("data", ("data",)) for s in ks), ks


def test_pick_layout_default_tp():
    for arch in list_archs():
        for shape in INPUT_SHAPES.values():
            assert pick_layout(get_config(arch), shape) == "tp"
