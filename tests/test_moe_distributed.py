"""Numeric validation of the shard_map MoE strategies (a2a / psum) against
the single-device path, executed on 8 fake host devices in a subprocess
(the device-count override must precede jax init, so it cannot run in this
process — same constraint as the dry-run)."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
from repro import sharding
from repro.configs import get_config
from repro.models.moe import init_moe, moe_forward

cfg = get_config("arctic-480b").reduced()
cfg = dataclasses.replace(
    cfg, moe=dataclasses.replace(cfg.moe, num_experts=4, top_k=2,
                                 capacity_factor=8.0))
params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
routed = {k: params[k] for k in ("router", "w_gate", "w_up", "w_down")}
B, S = 4, 8
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))

# reference: local single-device
y_ref, aux_ref = moe_forward(cfg, routed, x)

mesh = jax.make_mesh((2, 2), ("data", "model"))
for strategy, seq in (("a2a", True), ("psum", False)):
    with sharding.use_mesh(mesh, batch_axes=("data",), model_axis="model",
                           moe_strategy=strategy):
        y, aux = jax.jit(lambda xx: moe_forward(cfg, routed, xx))(x)
    err = float(jnp.abs(y - y_ref).max())
    aerr = abs(float(aux) - float(aux_ref))
    print(f"{strategy}: y_err={err:.2e} aux_err={aerr:.2e}")
    assert err < 1e-4, f"{strategy} diverges: {err}"
    # aux uses the standard per-device approximation (mean over shards of
    # the per-shard sum f_e*P_e) — a quadratic statistic, so it differs
    # from the global value by O(cross-shard covariance), not fp noise.
    assert aerr < 5e-4, f"{strategy} aux diverges: {aerr}"
print("DISTRIBUTED_MOE_OK")
"""


def test_moe_shard_map_strategies_match_local():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert "DISTRIBUTED_MOE_OK" in proc.stdout, (
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}")
