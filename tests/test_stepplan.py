"""The step-plan layer (ISSUE 4): one bucketed batch-execution plan API
shared by the live engine and the simulator's cost model.

Covers the planner contract (bucketing, resumable chunk cursors, the
§4.2.3 no-mixing invariant), jit-compile stability of the live
batched-bucketed prefill path, bit-identical chunked prefill on real
engines, the golden live-vs-sim per-iteration plan trace, and the single
``PerfModel.plan_time`` cost entry point."""
import jax
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.scheduling import LiveCluster
from repro.scheduling.actions import (Decode, MirrorSync, Prefill,
                                      PromoteReplica, StreamState)
from repro.scheduling.accellm import AcceLLMScheduler
from repro.scheduling.baselines import SarathiScheduler, VLLMScheduler
from repro.serving import InstanceEngine, Request
from repro.sim import H100, InstanceSpec, PerfModel, Simulator
from repro.sim.policies import SarathiPolicy
from repro.sim.workload import SimRequest
from repro.stepplan import (DecodePlan, MixedPlan, PlanError, Planner,
                            PrefillItem, PrefillPlan, TransferPlan,
                            bucket_len, prefill_part)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk(cfg, i, plen, new=3):
    return Request(prompt_len=plen, max_new_tokens=new,
                   prompt_tokens=jax.random.randint(
                       jax.random.fold_in(jax.random.PRNGKey(17), i),
                       (1, plen), 0, cfg.vocab_size))


# ---------------------------------------------------------------------------
# planner fakes
# ---------------------------------------------------------------------------


class _FakeInst:
    def __init__(self, lines=None, synced=None):
        self._lines = lines or {}
        self._synced = synced or {}

    def request_lines(self):
        return dict(self._lines)

    def replica_synced(self):
        return dict(self._synced)


class _FakeView:
    def __init__(self, insts, placements=None):
        self._insts = insts
        self._placements = placements or {}

    def instances(self):
        return self._insts

    def placements(self):
        return self._placements


# ---------------------------------------------------------------------------
# planner contract
# ---------------------------------------------------------------------------


def test_bucket_len_powers_of_two():
    assert bucket_len(1) == 16          # floor
    assert bucket_len(16) == 16
    assert bucket_len(17) == 32
    assert bucket_len(100) == 128
    assert bucket_len(100, cap=64) == 64


def test_planner_rejects_mixing_for_accellm():
    """The §4.2.3 invariant lives in the planner: a no-mix policy can
    never see prefill+decode co-scheduled on one instance."""
    planner = Planner.for_policy(AcceLLMScheduler())
    assert not planner.allow_mixed
    view = _FakeView([_FakeInst({7: 12})])
    acts = [Prefill(1, 0, prompt_len=8), Decode(0)]
    with pytest.raises(PlanError, match="4.2.3"):
        planner.compile(acts, view)
    # prefill alone and decode alone both compile fine
    assert isinstance(planner.compile([acts[0]], view)[0], PrefillPlan)
    assert isinstance(planner.compile([acts[1]], view)[0], DecodePlan)


def test_planner_mixes_for_vllm_and_prices_decode_from_ledger():
    planner = Planner.for_policy(VLLMScheduler())
    view = _FakeView([_FakeInst({3: 20, 1: 10})],
                     placements={1: (0, 1), 3: (0, None)})
    plans = planner.compile([Prefill(9, 0, prompt_len=30), Decode(0)], view)
    assert len(plans) == 1
    plan = plans[0]
    assert isinstance(plan, MixedPlan)
    assert plan.prefill.items == (PrefillItem(9, 30, 0, 30),)
    assert plan.prefill.bucket_len == 32
    assert plan.decode.lengths == (10, 20)    # sorted by rid
    assert plan.decode.mirrored == 1          # rid 1 has a replica


def test_planner_chunk_cursors_resume_across_compiles():
    planner = Planner.for_policy(SarathiScheduler(chunk_tokens=8))
    view = _FakeView([_FakeInst()])
    act = Prefill(5, 0, prompt_len=20)
    spans = []
    for _ in range(3):
        plans = planner.compile([act], view)
        it = plans[0].items[0]
        spans.append((it.start, it.end, it.completes))
    assert spans == [(0, 8, False), (8, 16, False), (16, 20, True)]
    assert planner.cursor(5) == 0             # cursor cleared on completion
    # budget spans items: in-progress first, remainder to the next prompt
    planner.compile([Prefill(6, 0, prompt_len=6)], view)
    plans = planner.compile([Prefill(7, 0, prompt_len=12),
                             Prefill(8, 0, prompt_len=12)], view)
    items = plans[0].items
    assert [(i.rid, i.start, i.end) for i in items] == [(7, 0, 8)]
    plans = planner.compile([Prefill(7, 0, prompt_len=12),
                             Prefill(8, 0, prompt_len=12)], view)
    items = plans[0].items
    assert [(i.rid, i.start, i.end) for i in items] == [(7, 8, 12), (8, 0, 4)]


def test_planner_wraps_transfers_with_ledger_lines():
    planner = Planner.for_policy(AcceLLMScheduler())
    view = _FakeView([_FakeInst({4: 33}), _FakeInst(synced={4: 30})])
    stream, mirror, promote = (StreamState(4, src=0, dst=1),
                               MirrorSync(4, primary=0, replica=1),
                               PromoteReplica(4, src=0, dst=1))
    plans = planner.compile([stream, mirror, promote], view)
    assert [type(p) for p in plans] == [TransferPlan] * 3
    assert plans[0].lines == 33 and plans[0].overlap_layers
    assert plans[1].lines == 3                # delta: synced 30 -> 33
    assert plans[2].lines == 0


# ---------------------------------------------------------------------------
# PerfModel.plan_time: the sim's only step-cost entry point
# ---------------------------------------------------------------------------


def test_plan_time_prices_all_plan_kinds():
    perf = PerfModel(get_config("llama2-70b"), InstanceSpec(H100, 4))
    pf = PrefillPlan(0, (PrefillItem(1, 100, 0, 100),
                         PrefillItem(2, 50, 0, 50)), 128)
    dc = DecodePlan(0, lengths=(200, 300), mirrored=0)
    t_iter = perf._decode_iter_time((200, 300))
    assert perf.plan_time(pf) == perf.prefill_time([100, 50])
    assert perf.plan_time(dc) == t_iter
    # the deprecated bare method routes through the same entry point
    with pytest.deprecated_call():
        assert perf.decode_step_time([200, 300]) == perf.plan_time(dc)
    assert perf.plan_time(MixedPlan(0, pf, dc)) == pytest.approx(
        perf.plan_time(pf) + perf.plan_time(dc))
    # a resumed chunk pays for its history attention (what the live
    # chunk path executes), but not for the whole prompt's quadratic
    chunk = PrefillPlan(0, (PrefillItem(1, 1024, 512, 1024),), 1024, 512)
    assert perf.plan_time(chunk) == perf.chunked_prefill_time([(512, 1024)])
    assert perf.plan_time(chunk) >= perf.prefill_time([512])
    assert perf.plan_time(chunk) <= perf.prefill_time([1024])
    # a (0, s) chunk degenerates to the whole-prompt cost exactly
    first = PrefillPlan(0, (PrefillItem(1, 1024, 0, 512),), 512, 512)
    assert perf.plan_time(first) == perf.prefill_time([512])
    # mirrored decodes may be bound by the pair link (Fig. 10)
    mirrored = DecodePlan(0, lengths=(200, 300), mirrored=2)
    t_link = 2 * perf.line_costs.mirror_bytes(1) / perf.inst.link_bw
    assert perf.plan_time(mirrored) == max(t_iter, t_link)
    # transfers: whole-state stream vs delta mirror vs free role flip
    stream = TransferPlan(0, StreamState(1, 0, 1), lines=400)
    assert perf.plan_time(stream) == perf.kv_transfer_time(400)
    sync = TransferPlan(0, MirrorSync(1, 0, 1), lines=1)
    assert perf.plan_time(sync) == pytest.approx(
        perf.line_costs.mirror_bytes(1) / perf.inst.link_bw)
    assert perf.plan_time(TransferPlan(0, PromoteReplica(1, 0, 1))) == 0.0


# ---------------------------------------------------------------------------
# jit-compile stability: compiles bounded by buckets, not prompt lengths
# ---------------------------------------------------------------------------


def test_prefill_compiles_bounded_by_buckets(setup):
    """A stream of >=16 distinct prompt lengths must compile at most one
    prefill kernel per (batch, bucket) shape — the seed path compiled one
    XLA program per distinct length."""
    cfg, params = setup
    eng = InstanceEngine(cfg, params, num_slots=2, kv_capacity=256)
    plens = list(range(5, 21)) + [40, 70]     # 18 distinct lengths
    for i, plen in enumerate(plens):
        slot = eng.prefill_request(_mk(cfg, i, plen))
        eng.release(slot)
    buckets = {bucket_len(p, cap=eng.kv_capacity) for p in plens}
    n_compiles = eng._jit_prefill_batched._cache_size()
    assert n_compiles <= len(buckets), (
        f"{n_compiles} prefill compiles for {len(plens)} lengths; "
        f"expected at most {len(buckets)} bucket shapes {sorted(buckets)}")
    assert len(buckets) < len(plens)          # the test must be non-trivial


def test_batched_prefill_matches_single_prefill(setup):
    """One padded multi-prompt call must produce the same greedy tokens
    as sequential single-prompt prefills."""
    cfg, params = setup
    plens = [6, 11, 9, 14]
    reqs_a = [_mk(cfg, i, p) for i, p in enumerate(plens)]
    reqs_b = [Request(prompt_len=r.prompt_len, max_new_tokens=3,
                      prompt_tokens=r.prompt_tokens) for r in reqs_a]
    eng_a = InstanceEngine(cfg, params, num_slots=4, kv_capacity=64)
    plan = PrefillPlan(0, tuple(
        PrefillItem(r.rid, r.prompt_len, 0, r.prompt_len, req=r)
        for r in reqs_a), bucket_len(max(plens), cap=64))
    done = eng_a.prefill_batch(plan)
    assert sorted(done) == sorted(r.rid for r in reqs_a)
    eng_b = InstanceEngine(cfg, params, num_slots=4, kv_capacity=64)
    for r in reqs_b:
        eng_b.prefill_request(r)
    for ra, rb in zip(reqs_a, reqs_b):
        assert ra.output_tokens == rb.output_tokens
    # and the decodes that follow agree too
    for _ in range(2):
        eng_a.decode()
        eng_b.decode()
    for ra, rb in zip(reqs_a, reqs_b):
        assert ra.output_tokens == rb.output_tokens


def test_release_clears_stale_last_token(setup):
    cfg, params = setup
    eng = InstanceEngine(cfg, params, num_slots=1, kv_capacity=64)
    req = _mk(cfg, 0, 8, new=1)
    slot = eng.prefill_request(req)
    assert eng.last_tokens[slot] != 0 or req.output_tokens[0] == 0
    eng.release(slot)
    assert eng.last_tokens[slot] == 0
    assert eng.lengths[slot] == 0


# ---------------------------------------------------------------------------
# chunked prefill on the live backend
# ---------------------------------------------------------------------------


def test_live_sarathi_chunks_and_matches_unchunked_tokens(setup):
    """A Sarathi run whose longest prompt exceeds chunk_tokens must (a)
    actually chunk on the live engines and (b) produce bit-identical
    output tokens to the unchunked greedy reference."""
    cfg, params = setup
    plens = [20, 6, 13]
    reqs = [_mk(cfg, i, p, new=3 + i % 2) for i, p in enumerate(plens)]

    def ref_tokens(r):
        eng = InstanceEngine(cfg, params, num_slots=1, kv_capacity=64)
        clone = Request(prompt_len=r.prompt_len,
                        max_new_tokens=r.max_new_tokens,
                        prompt_tokens=r.prompt_tokens)
        eng.prefill_request(clone)
        while not clone.done:
            eng.decode()
        return clone.output_tokens

    expected = {r.rid: ref_tokens(r) for r in reqs}
    cluster = LiveCluster(cfg, params, n_instances=1, num_slots=8,
                          kv_capacity=64, policy=SarathiScheduler(8))
    cluster.planner.trace = []
    for r in reqs:
        cluster.submit(r)
    done = cluster.run(max_steps=100)
    assert len(done) == len(reqs)
    for r in done:
        assert r.output_tokens == expected[r.rid], (
            f"rid {r.rid}: chunked tokens diverge from unchunked greedy")
    # the 20-token prompt must really have spanned iterations
    chunk_spans = [it for entry in cluster.planner.trace
                   if entry[0] in ("prefill", "mixed")
                   for it in (entry[2] if entry[0] == "prefill"
                              else entry[2][0])
                   if it[1] > 0]
    assert chunk_spans, "no resumed chunk in the plan trace"


def test_live_sarathi_serves_non_chunkable_stack():
    """A recurrent stack cannot resume a prompt mid-chunk; the live
    cluster must plan whole prompts (not crash mid-serve) when its
    engines lack chunk support."""
    cfg = get_config("xlstm-1.3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    cluster = LiveCluster(cfg, params, n_instances=1, num_slots=4,
                          kv_capacity=64, policy=SarathiScheduler(8))
    # the budget survives as a whole-prompt admission throttle
    assert not cluster.planner.chunk_execution
    assert cluster.planner.chunk_tokens == 8
    assert not cluster.engines[0].supports_chunked_prefill
    cluster.planner.trace = []
    reqs = [_mk(cfg, i, 20, new=2) for i in range(2)]  # > chunk budget
    for r in reqs:
        cluster.submit(r)
    done = cluster.run(max_steps=50)
    assert len(done) == 2
    assert all(len(r.output_tokens) == 2 for r in reqs)
    # every planned item is a whole prompt, throttled to one oversized
    # prompt per iteration
    pf_entries = [e for e in cluster.planner.trace
                  if e[0] in ("prefill", "mixed")]
    items = [it for e in pf_entries
             for it in (e[2] if e[0] == "prefill" else e[2][0])]
    assert all(start == 0 and end == 20 for _, start, end in items)
    assert len(pf_entries) == 2


def test_golden_plan_trace_live_vs_sim(setup):
    """Both backends must report the same per-iteration plan sequence
    for the same Sarathi workload: the planner — not each executor —
    decides what an iteration executes."""
    cfg, params = setup
    plens = [(20, 2), (6, 3), (13, 2)]
    reqs = [_mk(cfg, i, p, new=n) for i, (p, n) in enumerate(plens)]

    cluster = LiveCluster(cfg, params, n_instances=1, num_slots=8,
                          kv_capacity=64, policy=SarathiScheduler(8))
    cluster.planner.trace = []
    for r in reqs:
        cluster.submit(r)
    cluster.run(max_steps=100)
    live_trace = cluster.planner.trace

    # lock-step simulator adapter: one next_plan per live iteration, one
    # queue admission per iteration (the live executor admits at most
    # len(instances)=1 per step), applying completions the way the live
    # executor does (prefill joins decode within the same iteration)
    pol = SarathiPolicy(8)
    perf = PerfModel(cfg, InstanceSpec(H100, 4))
    sim = Simulator(pol, perf, n_instances=1, max_batch=8)
    sim.kick = lambda inst: None
    pol.planner.trace = []
    inst = sim.instances[0]
    arrivals = iter([SimRequest(rid=r.rid, arrival=0.0,
                                prompt_len=r.prompt_len,
                                decode_len=r.max_new_tokens) for r in reqs])
    for _ in range(100):
        nxt = next(arrivals, None)
        if nxt is not None:
            inst.prefill_queue.append(nxt)
        plan = pol.next_plan(inst)
        if plan is None:
            if nxt is None and not inst.prefill_queue \
                    and not inst.decode_batch:
                break
            continue
        pf = prefill_part(plan)
        if pf is not None:
            # completing requests left the queue at plan-compile time
            finished = [it.req for it in pf.items if it.completes]
            for r in finished:
                r.generated += 1
            pol.on_prefill_done(inst, finished)
        # live executes the decode phase on every non-exclusive instance
        # AFTER prefill joins — advance whatever is resident now
        for rid, r in list(inst.decode_batch.items()):
            r.generated += 1
            if r.done:
                del inst.decode_batch[rid]
    assert live_trace == pol.planner.trace, (
        "the two backends compiled different per-iteration plans:\n"
        f"live: {live_trace}\nsim:  {pol.planner.trace}")
    kinds = {e[0] for e in live_trace}
    assert "mixed" in kinds and "prefill" in kinds
