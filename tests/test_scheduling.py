"""The shared scheduling kernel: registry, eviction policy, and the
golden-trace consistency guarantee — the AcceLLM kernel must make
IDENTICAL routing, placement and rebalancing decisions whether it is
driven by the live-engine executor or by the simulator adapter on the
same request trace.  (This is the check that the policy lives in exactly
one place: any logic re-implemented per backend would drift and break
the trace equality.)"""
import jax
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.scheduling import (AcceLLMScheduler, EvictReplica, LiveCluster,
                              get_policy, policy_names)
from repro.serving import Request
from repro.sim import H100, InstanceSpec, PerfModel, Simulator
from repro.sim.policies import AcceLLMPolicy
from repro.sim.workload import SimRequest


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_has_all_policies():
    assert policy_names() == ["accellm", "accellm-vec", "sarathi",
                              "splitwise", "splitwise-vec", "ulb",
                              "ulb-vec", "vllm", "vllm-vec"]
    for name in policy_names():
        pol = get_policy(name)
        assert pol.name == name
    with pytest.raises(KeyError):
        get_policy("nope")


# ---------------------------------------------------------------------------
# eviction: most bytes freed (the longest request's replica)
# ---------------------------------------------------------------------------


class _FakeView:
    def __init__(self, index, replicas):
        self.index = index
        self._replicas = replicas

    def replica_weights(self):
        return self._replicas


def test_eviction_victim_is_longest_request():
    kernel = AcceLLMScheduler()
    views = [_FakeView(0, {3: 100.0, 9: 400.0}),
             _FakeView(1, {5: 250.0})]
    victims = kernel._eviction_victims(views, need=1)
    assert victims == [EvictReplica(rid=9, instance=0)]
    # ties break toward the lowest rid, deterministically
    views = [_FakeView(0, {7: 100.0, 2: 100.0})]
    assert kernel._eviction_victims(views, need=1)[0].rid == 2


def test_sim_eviction_goes_through_kernel():
    perf = PerfModel(get_config("llama2-70b"), InstanceSpec(H100, 4))
    sim = Simulator(AcceLLMPolicy(), perf, n_instances=2)
    pol = sim.policy
    short = SimRequest(rid=0, arrival=0.0, prompt_len=10, decode_len=4)
    long = SimRequest(rid=1, arrival=0.0, prompt_len=500, decode_len=4)
    inst = sim.instances[0]
    inst.replicas = {0: short, 1: long}
    pol.placement = {0: (1, 0), 1: (1, 0)}
    pol._evict_replica(inst)
    assert 1 not in inst.replicas, "kernel must evict the longest request"
    assert 0 in inst.replicas
    assert pol.placement[1] == (1, None)


# ---------------------------------------------------------------------------
# golden trace: live executor vs simulator adapter
# ---------------------------------------------------------------------------

# (prompt_len, decode_len) per arrival; interleaved with bare decode ticks.
_TRACE = [("arrive", 8, 4), ("tick",), ("arrive", 12, 6), ("arrive", 6, 5),
          ("tick",), ("arrive", 10, 3), ("tick",), ("arrive", 7, 6),
          ("arrive", 9, 4), ("tick",)]


def _run_live_trace(cfg, params, kernel, n_instances):
    cluster = LiveCluster(cfg, params, n_instances=n_instances, num_slots=8,
                          kv_capacity=256, policy=kernel)
    key = jax.random.PRNGKey(7)
    rids = []
    for i, op in enumerate(_TRACE):
        if op[0] == "arrive":
            plen, dlen = op[1], op[2]
            req = Request(prompt_len=plen, max_new_tokens=dlen,
                          prompt_tokens=jax.random.randint(
                              jax.random.fold_in(key, i), (1, plen), 0,
                              cfg.vocab_size))
            rids.append(req.rid)
            cluster.submit(req)
        cluster.step()
    steps = 0
    while cluster.pending() and steps < 50:
        cluster.step()
        steps += 1
    assert not cluster.pending()
    return rids, steps


def _run_sim_trace(cfg, rids, extra_ticks, n_instances):
    """Drive the *simulator adapter* through the same trace, lock-step:
    arrivals route+prefill via the adapter (kernel decides), each tick
    advances every decoding instance one token and fires the adapter's
    decode-done hook (replica cleanup + kernel rebalancing).  The
    instance chosen for prefill skips decoding that tick, exactly like
    the live executor's exclusive-prefill role."""
    kernel = AcceLLMScheduler()
    kernel.trace = []
    perf = PerfModel(cfg, InstanceSpec(H100, 4))
    sim = Simulator(AcceLLMPolicy(kernel=kernel), perf,
                    n_instances=n_instances)
    sim.kick = lambda inst: None          # event mechanics not under test
    pol = sim.policy

    def tick(skip_iid=None):
        finished = {}
        for inst in sim.instances:
            if inst.iid == skip_iid:
                continue
            done_here = []
            for rid, r in list(inst.decode_batch.items()):
                r.generated += 1
                if r.done:
                    del inst.decode_batch[rid]
                    done_here.append(r)
            finished[inst.iid] = done_here
        for inst in sim.instances:
            if inst.iid in finished:
                pol.on_decode_done(inst, finished[inst.iid])

    arrivals = iter(rids)
    for op in _TRACE:
        skip = None
        if op[0] == "arrive":
            r = SimRequest(rid=next(arrivals), arrival=0.0,
                           prompt_len=op[1], decode_len=op[2])
            inst = pol.route(r)
            r.generated = 1               # the prefill's first token
            pol.on_prefill_done(inst, [r])
            skip = inst.iid
        tick(skip_iid=skip)
    for _ in range(extra_ticks):
        tick()
    return kernel.trace


@pytest.mark.parametrize("n_instances", [2, 4])
def test_golden_trace_live_vs_sim(setup, n_instances):
    cfg, params = setup
    live_kernel = AcceLLMScheduler()
    live_kernel.trace = []
    rids, extra = _run_live_trace(cfg, params, live_kernel, n_instances)
    sim_trace = _run_sim_trace(cfg, rids, extra, n_instances)
    assert live_kernel.trace == sim_trace, (
        "shared kernel made different decisions on the two backends:\n"
        f"live: {live_kernel.trace}\nsim:  {sim_trace}")
    # the trace must actually exercise the interesting decisions
    kinds = {entry[0] for entry in live_kernel.trace}
    assert {"route", "place"} <= kinds
    if n_instances == 2:
        assert "rebalance" in kinds


# ---------------------------------------------------------------------------
# live executor runs baseline policies end to end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["vllm", "splitwise", "sarathi"])
def test_live_cluster_runs_baseline_policies(setup, name):
    cfg, params = setup
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=6,
                          kv_capacity=128, policy=name)
    key = jax.random.PRNGKey(3)
    reqs = []
    for i in range(5):
        plen = 6 + (i % 4)
        reqs.append(Request(prompt_len=plen, max_new_tokens=3 + (i % 3),
                            prompt_tokens=jax.random.randint(
                                jax.random.fold_in(key, i), (1, plen), 0,
                                cfg.vocab_size)))
    for r in reqs:
        cluster.submit(r)
    done = cluster.run(max_steps=200)
    assert len(done) == 5
    for r in done:
        assert len(r.output_tokens) == r.max_new_tokens
    # baselines never touch the redundancy machinery
    assert cluster.stats["mirror_syncs"] == 0
    assert cluster.stats["replica_promotions"] == 0


def test_api_serve_facade(setup):
    from repro.api import ServeSpec, serve
    cfg, params = setup
    spec = ServeSpec(arch="starcoder2-3b", policy="accellm", n_instances=2,
                     num_slots=6, kv_capacity=128, n_requests=4,
                     workload="light", max_steps=200)
    report = serve(spec, cfg=cfg, params=params)
    assert report.all_finished
    assert report.stats["prefills"] == 4
    assert report.ttfts().size == 4


# ---------------------------------------------------------------------------
# golden trace, traffic layer: one WorkloadSpec seed, two backends
# ---------------------------------------------------------------------------


def _traffic_spec():
    from repro.workloads import Bursty, UniformLengths, WorkloadSpec
    return WorkloadSpec(
        arrival=Bursty(rate_on=1.0, duration=8.0, mean_on=3.0, mean_off=2.0),
        lengths=UniformLengths(prompt=(6, 12), decode=(3, 6)),
        name="golden")


def test_workload_spec_identical_stream_on_both_backends(setup):
    """The same (WorkloadSpec, seed) must hand the live cluster and the
    simulator the identical request sequence — same rids, arrival stamps
    and lengths — with no per-backend workload code."""
    cfg, _ = setup
    spec = _traffic_spec()
    live_stream = list(spec.source(seed=11, cfg=cfg))     # with tokens
    sim_stream = list(spec.source(seed=11))               # array-free
    assert [(r.rid, r.arrival, r.prompt_len, r.max_new_tokens)
            for r in live_stream] == \
        [(r.rid, r.arrival, r.prompt_len, r.max_new_tokens)
         for r in sim_stream]
    assert all(r.prompt_tokens is not None for r in live_stream)
    assert all(r.prompt_tokens is None for r in sim_stream)


def test_open_loop_source_drives_both_backends(setup):
    """End to end: the one spec runs open-loop on real engines (iteration
    clock) and on the simulator (modeled seconds); both complete the
    identical request set."""
    from repro.workloads import SLO, slo_summary
    cfg, params = setup
    spec = _traffic_spec()
    n_expected = len(list(spec.source(seed=11)))
    assert n_expected >= 2, "trace must exercise the lifecycle"

    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=8,
                          kv_capacity=256, policy=AcceLLMScheduler())
    live_done = cluster.run(max_steps=100,
                            source=spec.source(seed=11, cfg=cfg))
    assert len(live_done) == n_expected
    # arrival stamps survive admission (not re-stamped to iteration ticks)
    assert sorted(r.arrival for r in live_done) == \
        sorted(r.arrival for r in spec.source(seed=11))
    # open loop means arrivals were admitted over time, not all at step 1
    assert cluster.timeline[0].queue_depth < n_expected
    s = slo_summary(live_done, SLO(ttft=50.0), duration=cluster.now,
                    unit=cluster.clock.unit)
    assert s.attainment == 1.0

    perf = PerfModel(cfg, InstanceSpec(H100, 4))
    sim = Simulator(AcceLLMPolicy(), perf, n_instances=2)
    sim_done = sim.run(source=spec.source(seed=11), horizon=1000.0)
    assert sorted(r.rid for r in sim_done) == \
        sorted(r.rid for r in live_done)
    assert sim.clock.unit == "s" and cluster.clock.unit == "iters"


def test_live_open_loop_counts_undelivered(setup):
    """max_steps elapsing mid-stream must be visible: the requests the
    source still held are counted, not silently dropped."""
    from repro.workloads import Poisson, UniformLengths, WorkloadSpec
    cfg, params = setup
    spec = WorkloadSpec(arrival=Poisson(rate=1.0, duration=50.0),
                        lengths=UniformLengths(prompt=(4, 6), decode=(2, 3)))
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=8,
                          kv_capacity=256, policy=AcceLLMScheduler())
    cluster.run(max_steps=5, source=spec.source(seed=0, cfg=cfg))
    n_total = len(list(spec.source(seed=0)))
    assert cluster.undelivered > 0
    assert len(cluster._submitted) + cluster.undelivered == n_total
